"""Journal v2 (compact, indexed) + v1 interop (paper §4.1 restart).

v2 stores the space hash plus range-compressed completed instance
indices — O(completed ranges), never O(N_W) — while v1 journals keep
resuming transparently under the streaming engine and vice versa.  The
crash window between ``mark_complete`` (sidecar append) and compaction
(base rewrite) must never lose a completion.
"""
import json

import pytest

from repro.core import (
    LocalTransport, ParameterStudy, StudyJournal, compress_ranges,
    expand_ranges, parse_yaml,
)

SPEC = """
work:
  args:
    x: [1, 2, 3]
    y: [10, 20]
  command: echo ${args:x} ${args:y}
"""


def make_study(tmp_path, registry=None, name="s"):
    return ParameterStudy(parse_yaml(SPEC), registry=registry,
                          root=tmp_path, name=name)


class TestRanges:
    def test_compress_folds_contiguous_spans(self):
        assert compress_ranges([0, 1, 2, 5, 7, 8]) == [[0, 2], [5, 5], [7, 8]]
        assert compress_ranges([]) == []
        assert compress_ranges([3, 3, 3]) == [[3, 3]]

    def test_expand_is_inverse(self):
        for indices in ([], [0], [0, 1, 2], [5, 9, 10, 11, 40]):
            assert sorted(expand_ranges(compress_ranges(indices))) \
                == sorted(set(indices))

    def test_contiguous_completion_is_o1_bytes(self, tmp_path):
        j = StudyJournal(tmp_path / "j.json")
        j.save_indexed("hash", 100_000, {"work": range(100_000)}, {})
        assert j.path.stat().st_size < 300   # one [start, end] span
        state = j.load_state()
        assert len(state.completed_indices["work"]) == 100_000


class TestSaveLoadV2:
    def test_roundtrip(self, tmp_path):
        j = StudyJournal(tmp_path / "j.json")
        j.save_indexed("abc123", 50, {"work": {0, 1, 2, 10}},
                       {"name": "n"}, hosts={"work@x": "h0"})
        state = j.load_state()
        assert state.version == 2
        assert state.space_hash == "abc123"
        assert state.n_instances == 50
        assert state.completed_indices == {"work": {0, 1, 2, 10}}
        assert state.meta["name"] == "n"
        assert state.hosts == {"work@x": "h0"}
        assert state.instances is None

    def test_legacy_load_rejects_v2(self, tmp_path):
        j = StudyJournal(tmp_path / "j.json")
        j.save_indexed("abc", 5, {}, {})
        with pytest.raises(ValueError, match="v2"):
            j.load()

    def test_load_state_reads_v1(self, tmp_path):
        j = StudyJournal(tmp_path / "j.json")
        j.save([{"a": 1}], {"work@x"}, {"name": "n"})
        state = j.load_state()
        assert state.version == 1
        assert state.instances == [{"a": 1}]
        assert state.completed == {"work@x"}
        assert state.completed_indices is None


class TestCrashWindow:
    def test_log_survives_missed_compaction(self, tmp_path):
        """Completions appended after the last compaction (the crash
        window between ``mark_complete`` and the final ``save_indexed``)
        must fold back in on the next load."""
        j = StudyJournal(tmp_path / "j.json")
        j.save_indexed("h", 10, {"work": {0, 1}}, {})
        j.mark_complete("work@aaa", index=2, task="work")
        j.mark_complete("work@bbb", host="h7", index=3, task="work")
        # a fresh object (≈ restarted process) folds base + sidecar log
        state = StudyJournal(tmp_path / "j.json").load_state()
        assert state.completed_indices["work"] == {0, 1, 2, 3}
        assert state.completed == {"work@aaa", "work@bbb"}
        assert state.hosts["work@bbb"] == "h7"

    def test_study_crash_between_mark_and_compaction(self, tmp_path):
        """Kill the engine mid-study with a non-Exception (so fault
        isolation cannot swallow it) — completed indices must survive
        into the resumed run, which only re-admits the remainder."""
        class Crash(BaseException):
            pass

        def runner(combo):
            if combo["args:x"] == 3:
                raise Crash("power loss")
            return 0

        study = make_study(tmp_path, {"work": runner}, name="crash")
        with pytest.raises(Crash):
            study.run(window=2)
        # the final compaction never ran: state lives in base + log
        assert study.journal.log_path.exists()

        resumed = make_study(tmp_path, {"work": lambda c: 0}, name="crash")
        resumed.run(window=2, resume=True)
        state = resumed.journal.load_state()
        assert len(state.completed_indices["work"]) == 6
        assert resumed.last_run_stats["skipped_complete"] >= 1
        assert not resumed.journal.log_path.exists()  # compacted


class TestMigration:
    def test_v1_journal_resumes_windowed(self, tmp_path):
        """Eager (v1) study interrupted, resumed through the streaming
        path: completed node ids migrate to space indices."""
        boom = {"armed": True}

        def worker(combo):
            if boom["armed"] and combo["args:x"] == 3:
                raise RuntimeError("node died")
            return combo["args:x"]

        study = make_study(tmp_path, {"work": worker}, name="mig")
        study.run(max_retries=0)       # eager: writes v1
        assert json.loads(study.journal.path.read_text())["version"] == 1

        boom["armed"] = False
        resumed = make_study(tmp_path, {"work": worker}, name="mig")
        ran = []
        res = resumed.run(window=2, resume=True,
                          runner=lambda n: ran.append(n.id) or 0)
        assert len(ran) == 2           # only the two failed x==3 instances
        assert all(r.status == "ok" for r in res.values())
        # and the journal is now compact v2 with every instance folded
        doc = json.loads(resumed.journal.path.read_text())
        assert doc["version"] == 2
        assert doc["completed"]["work"] == [[0, 5]]

    def test_provenance_indices_mirror_journal(self, tmp_path):
        """``StudyDB.completed_indices()`` (recovery from raw provenance
        records) must agree with the journal's completed indices — the
        two derivations of task → space indices may not drift."""
        study = make_study(tmp_path, {"work": lambda c: 0}, name="prov")
        study.run(window=2)
        assert study.db.completed_indices() \
            == study.journal.load_state().completed_indices

    def test_crash_state_v1_journal_resumes_windowed(self, tmp_path):
        """A v1 journal whose base was only ever written by
        ``mark_complete`` (empty instance list, completions solely in
        the sidecar log — e.g. a lost base write, or standalone journal
        use) must still resume windowed: completed cids resolve by
        streaming the space instead of the missing instance list."""
        from repro.core import combo_id

        study = make_study(tmp_path, {"work": lambda c: 0}, name="v1crash")
        space = study.space()
        # completions recorded against a journal with no saved base
        for i in (0, 1, 2, 3):
            cid = combo_id(space.combo_at(i))
            study.journal.mark_complete(f"work@{cid}")
        doc = json.loads(study.journal.path.read_text())
        assert doc["version"] == 1 and doc["instances"] == []
        assert study.journal.log_path.exists()

        resumed = make_study(tmp_path, {"work": lambda c: 0}, name="v1crash")
        ran = []
        resumed.run(window=2, resume=True,
                    runner=lambda n: ran.append(n.id) or 0)
        assert len(ran) == 2           # only the two unrecorded instances
        assert resumed.last_run_stats["skipped_complete"] == 4

    def test_v2_journal_resumes_eager(self, tmp_path):
        """Streaming (v2) study resumed through the eager path:
        completed indices reconstruct node ids via combo_at."""
        study = make_study(tmp_path, {"work": lambda c: 0}, name="back")
        study.run(window=2)
        resumed = make_study(tmp_path, {"work": lambda c: 0}, name="back")
        ran = []
        res = resumed.run(resume=True,
                          runner=lambda n: ran.append(n.id) or 0)
        assert ran == []               # everything already complete
        assert len(res) == 6
        assert all(r.attempts == 0 for r in res.values())

    def test_space_hash_mismatch_refuses_resume(self, tmp_path):
        study = make_study(tmp_path, {"work": lambda c: 0}, name="drift")
        study.run(window=2)
        changed = ParameterStudy(parse_yaml("""
work:
  args:
    x: [1, 2, 3, 4]
    y: [10, 20]
  command: echo ${args:x} ${args:y}
"""), registry={"work": lambda c: 0}, root=tmp_path, name="drift")
        with pytest.raises(ValueError, match="journal was written for space"):
            changed.run(window=2, resume=True)
        # the eager path honors the same guarantee (a stale v2 journal
        # must not silently mark the wrong study's instances complete)
        with pytest.raises(ValueError, match="journal was written for space"):
            changed.run(resume=True)


class TestShardedResume:
    """Crash/resume with the sharded journal+DB layout the engine picks
    for parallel pools (lane/process, slots > 1)."""

    SH_SPEC = """
sh:
  args:
    n: [1, 2, 3, 4, 5, 6]
  command: echo v-${args:n}
"""

    def test_lane_crash_with_shards_resumes_merged(self, tmp_path):
        """A lane run (slots=2 → 2 journal/DB shards) interrupted
        mid-study leaves per-shard segments on disk; a fresh resume —
        on a different, unsharded backend — folds every segment and
        re-admits only the remainder."""
        class Stop(Exception):
            pass

        seen = []

        def tripwire(res):
            seen.append(res.id)
            if len(seen) == 3:
                raise Stop

        study = ParameterStudy(parse_yaml(self.SH_SPEC), root=tmp_path,
                               name="shcrash")
        with pytest.raises(Stop):
            study.run(pool="lane", slots=2, window=1, on_result=tripwire)
        # the sharded layout is actually on disk (no final compaction)
        log = study.journal.log_path
        assert log.with_name(log.name + ".s1").exists()
        done_before = len(
            StudyJournal(study.journal.path).load_state()
            .completed_indices["sh"])
        assert done_before >= 3

        resumed = ParameterStudy(parse_yaml(self.SH_SPEC), root=tmp_path,
                                 name="shcrash")
        res = resumed.run(window=2, resume=True)    # inline: one shard
        assert all(r.status == "ok" for r in res.values())
        assert resumed.last_run_stats["skipped_complete"] == done_before
        final = resumed.journal.load_state()
        assert len(final.completed_indices["sh"]) == 6
        # compaction folded and removed every segment
        assert not log.exists()
        assert not log.with_name(log.name + ".s1").exists()
        # provenance: sharded + resumed record segments merge to the
        # full set with latest-wins intact
        assert resumed.db.completed_indices()["sh"] == set(range(6))

    def test_v1_journal_migrates_to_sharded_v2(self, tmp_path):
        """v1 → v2 migration composes with sharding: an eager (v1)
        study interrupted mid-run resumes through the windowed engine on
        a sharded lane backend and compacts to a clean v2 base."""
        class Stop(Exception):
            pass

        seen = []

        def tripwire(res):
            seen.append(res.id)
            if len(seen) == 3:
                raise Stop

        study = ParameterStudy(parse_yaml(self.SH_SPEC), root=tmp_path,
                               name="shmig")
        with pytest.raises(Stop):
            study.run(on_result=tripwire)       # eager path: v1 journal
        assert json.loads(study.journal.path.read_text())["version"] == 1

        resumed = ParameterStudy(parse_yaml(self.SH_SPEC), root=tmp_path,
                                 name="shmig")
        res = resumed.run(pool="lane", slots=2, window=2, resume=True)
        assert all(r.status == "ok" for r in res.values())
        assert resumed.last_run_stats["skipped_complete"] == 3
        doc = json.loads(resumed.journal.path.read_text())
        assert doc["version"] == 2
        assert doc["completed"]["sh"] == [[0, 5]]
        # no sidecar segments survive the final compaction
        log = resumed.journal.log_path
        assert not log.exists()
        assert not log.with_name(log.name + ".s1").exists()


class TestResumeAcrossPools:
    SH_SPEC = """
sh:
  args:
    n: [1, 2, 3, 4, 5, 6]
  command: echo v-${args:n}
"""

    def _interrupt_midway(self, tmp_path, name, window):
        class Crash(BaseException):
            pass

        seen = []

        def runner(node):
            if len(seen) >= 3:
                raise Crash("mid-study interrupt")
            seen.append(node.id)
            return 0

        study = ParameterStudy(parse_yaml(self.SH_SPEC), root=tmp_path,
                               name=name)
        with pytest.raises(Crash):
            study.run(window=window, runner=runner)
        return study

    def test_inline_crash_resumes_on_ssh_pool(self, tmp_path):
        """Indices journaled by an inline windowed run survive a crash
        and resume on a completely different backend (ssh over the
        no-network LocalTransport fake)."""
        self._interrupt_midway(tmp_path, "xpool", window=2)
        resumed = ParameterStudy(parse_yaml(self.SH_SPEC), root=tmp_path,
                                 name="xpool")
        state = resumed.journal.load_state()
        done_before = set(state.completed_indices["sh"])
        assert len(done_before) == 3

        res = resumed.run(window=2, resume=True, pool="ssh",
                          hosts=["h0", "h1"], ppnode=1,
                          transport=LocalTransport())
        assert all(r.status == "ok" for r in res.values())
        assert resumed.last_run_stats["skipped_complete"] == 3
        final = resumed.journal.load_state()
        assert len(final.completed_indices["sh"]) == 6
        assert done_before <= final.completed_indices["sh"]

    def test_ssh_run_resumes_inline(self, tmp_path):
        study = ParameterStudy(parse_yaml(self.SH_SPEC), root=tmp_path,
                               name="xpool2")
        res = study.run(window=3, pool="ssh", hosts=["h0"], ppnode=2,
                        transport=LocalTransport())
        assert all(r.status == "ok" for r in res.values())
        # now resume inline: nothing left, hosts preserved from the run
        resumed = ParameterStudy(parse_yaml(self.SH_SPEC), root=tmp_path,
                                 name="xpool2")
        resumed.run(window=3, resume=True)
        assert resumed.last_run_stats["skipped_complete"] == 6
        assert len(resumed.journal.hosts()) == 6
