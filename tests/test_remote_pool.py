"""SSH worker pool via the in-process LocalTransport fake: slot
accounting (hosts × ppnode), out-of-order completion, host failure →
quarantine + retry on another host, and the pool-level cancel hook that
releases remote resources for abandoned dispatches."""
import threading
import time

import pytest

from repro.core import (
    LocalTransport, ParameterStudy, Scheduler, ShellResult, SSHWorkerPool,
    TaskDAG, TaskNode, make_pool, parse_yaml,
)
from repro.core.remote import SSHTransport


def make_dag(names, command=None):
    dag = TaskDAG()
    for name in names:
        dag.add(TaskNode(id=name, task=name, combo={},
                         payload={"command": command or f"run {name}"}))
    return dag


def render(node):
    return node.payload["command"], {}


def run(dag, pool, **kw):
    sched = Scheduler(slots=pool.slots, **kw)
    try:
        return sched.execute(dag, runner=None, pool=pool)
    finally:
        pool.shutdown()


class TestSlotAccounting:
    def test_slots_is_hosts_times_ppnode(self):
        pool = SSHWorkerPool(["a", "b", "c"], ppnode=2,
                             transport=LocalTransport(), render=render)
        try:
            assert pool.slots == 6
        finally:
            pool.shutdown()

    def test_hosts_string_form(self):
        pool = SSHWorkerPool("a, b", ppnode=2,
                             transport=LocalTransport(), render=render)
        try:
            assert pool.slots == 4 and pool.hosts == ["a", "b"]
        finally:
            pool.shutdown()

    def test_concurrency_bounded_per_host_and_global(self):
        lock = threading.Lock()
        cur = {"all": 0, "a": 0, "b": 0}
        peak = {"all": 0, "a": 0, "b": 0}

        def hook(host, command):
            with lock:
                cur["all"] += 1
                cur[host] += 1
                peak["all"] = max(peak["all"], cur["all"])
                peak[host] = max(peak[host], cur[host])
            time.sleep(0.03)
            with lock:
                cur["all"] -= 1
                cur[host] -= 1
            return ShellResult(0, host, "", 0.03)

        pool = SSHWorkerPool(["a", "b"], ppnode=2,
                             transport=LocalTransport(hook=hook),
                             render=render)
        results = run(make_dag([f"t{i:02d}" for i in range(16)]), pool)
        assert all(r.status == "ok" for r in results.values())
        assert peak["all"] <= 4 and peak["a"] <= 2 and peak["b"] <= 2
        assert peak["all"] >= 2      # real overlap happened
        hosts_used = {r.host for r in results.values()}
        assert hosts_used == {"a", "b"}

    def test_per_task_host_recorded(self):
        pool = SSHWorkerPool(["x1", "x2"], ppnode=1,
                             transport=LocalTransport(
                                 hook=lambda h, c: ShellResult(0, h, "", 0)),
                             render=render)
        results = run(make_dag(["p", "q", "r"]), pool)
        for r in results.values():
            assert r.host in ("x1", "x2")
            assert r.value.stdout == r.host


class TestOutOfOrderCompletion:
    def test_slow_first_dispatch_finishes_last(self):
        def hook(host, command):
            time.sleep(0.2 if "aa" in command else 0.01)
            return ShellResult(0, "", "", 0)

        pool = SSHWorkerPool(["h1", "h2"], ppnode=1,
                             transport=LocalTransport(hook=hook),
                             render=render)
        results = run(make_dag(["aa", "bb", "cc", "dd"]), pool)
        assert all(r.status == "ok" for r in results.values())
        # "aa" dispatched first but completed after later tasks
        assert results["aa"].finished > results["dd"].finished


class TestHostFailure:
    def test_failed_host_quarantined_and_tasks_retry_elsewhere(self):
        # the good host works slowly so the bad lane is guaranteed to
        # pick up at least one task from the queue before it drains
        def hook(h, c):
            time.sleep(0.05)
            return ShellResult(0, h, "", 0)

        # probation=0.0: legacy immediate permanent quarantine (the
        # probation path has its own coverage in test_chaos.py)
        pool = SSHWorkerPool(["bad", "good"], ppnode=1,
                             transport=LocalTransport(
                                 fail_hosts=["bad"], hook=hook),
                             render=render, probation=0.0)
        results = run(make_dag(["t1", "t2", "t3", "t4", "t5", "t6"]), pool,
                      max_retries=2)
        assert all(r.status == "ok" for r in results.values())
        assert {r.host for r in results.values()} == {"good"}
        assert pool.dead_hosts == {"bad"}
        retried = [r for r in results.values() if r.attempts > 1]
        assert retried, "the bad host should have failed at least one attempt"

    def test_all_hosts_down_terminates_with_failures(self):
        pool = SSHWorkerPool(["a", "b"], ppnode=1,
                             transport=LocalTransport(fail_hosts=["a", "b"]),
                             render=render)
        results = run(make_dag(["t1", "t2", "t3"]), pool, max_retries=1)
        assert all(r.status in ("failed", "skipped")
                   for r in results.values())
        failed = [r for r in results.values() if r.status == "failed"]
        assert failed and all("host" in (r.error or "")
                              or "no live hosts" in (r.error or "")
                              for r in failed)

    def test_missing_command_fails_cleanly(self):
        dag = TaskDAG()
        dag.add(TaskNode(id="n", task="n", combo={}, payload={}))
        pool = SSHWorkerPool(["h"], ppnode=1, transport=LocalTransport(),
                             render=lambda node: (None, {}))
        results = run(dag, pool, max_retries=0)
        assert results["n"].status == "failed"
        assert "no shell command" in results["n"].error


class TestCancel:
    def test_cancel_releases_host_slot(self):
        gate = threading.Event()

        def hook(host, command):
            if command == "run blocked":
                gate.wait(5)
            return ShellResult(0, "", "", 0)

        pool = SSHWorkerPool(["h"], ppnode=1,
                             transport=LocalTransport(hook=hook),
                             render=render)
        try:
            blocked = TaskNode(id="blocked", task="blocked", combo={},
                               payload={"command": "run blocked"})
            after = TaskNode(id="after", task="after", combo={},
                             payload={"command": "run after"})
            pool.submit(0, None, [blocked])
            time.sleep(0.05)
            pool.cancel(0)
            gate.set()
            ev = pool.next_event(timeout=2)
            assert ev is not None and ev.token == 0
            # the lane is free again: new work flows
            pool.submit(1, None, [after])
            ev = pool.next_event(timeout=2)
            assert ev is not None and ev.token == 1 and ev.errors == [None]
        finally:
            pool.shutdown()

    def test_speculative_loser_gets_pool_cancel(self):
        lock = threading.Lock()
        gate = threading.Event()
        attempts = {"n": 0}

        def hook(host, command):
            if command == "run zz":
                with lock:
                    attempts["n"] += 1
                    first = attempts["n"] == 1
                if first:
                    gate.wait(10)     # the straggler copy
                return ShellResult(0, "zz", "", 0)
            time.sleep(0.05)
            return ShellResult(0, "", "", 0)

        class SpyPool(SSHWorkerPool):
            cancelled: list = []

            def cancel(self, token):
                SpyPool.cancelled.append(token)
                super().cancel(token)

        SpyPool.cancelled = []
        pool = SpyPool(["h1", "h2"], ppnode=1,
                       transport=LocalTransport(hook=hook), render=render)
        dag = make_dag([f"a{i}" for i in range(6)] + ["zz"])
        try:
            sched = Scheduler(slots=pool.slots, speculate=True,
                              straggler_factor=2.0, max_retries=1)
            results = sched.execute(dag, runner=None, pool=pool)
            assert results["zz"].status == "ok"
            assert results["zz"].speculative
            assert SpyPool.cancelled, \
                "losing duplicate must be cancelled at the pool"
        finally:
            gate.set()
            pool.shutdown()


class TestStudyIntegration:
    WDL = """
    ping:
      environ:
        MODE: ["x", "y"]
      n: ["1:2"]
      command: echo ${n}.${environ:MODE}
    """

    def test_study_over_ssh_pool_records_journal_hosts(self, tmp_path):
        study = ParameterStudy(parse_yaml(self.WDL), root=tmp_path,
                               name="sshstudy")
        results = study.run(pool="ssh", hosts=["a", "b"], ppnode=2,
                            transport=LocalTransport())
        assert len(results) == 4
        assert all(r.status == "ok" for r in results.values())
        assert {r.host for r in results.values()} <= {"a", "b"}
        hosts = study.journal.hosts()
        assert set(hosts) == set(results)
        assert set(hosts.values()) <= {"a", "b"}
        # provenance records carry the host too
        recs = {r["task_id"]: r for r in study.db.records()}
        assert all(recs[rid]["host"] in ("a", "b") for rid in results)

    def test_wdl_hosts_keyword_drives_the_pool(self, tmp_path):
        wdl = """
        ping:
          hosts: [u, v]
          ppnode: 2
          n: ["1:2"]
          command: echo ${n}
        """
        study = ParameterStudy(parse_yaml(wdl), root=tmp_path, name="wdlhosts")
        results = study.run(pool="ssh", transport=LocalTransport())
        assert {r.host for r in results.values()} <= {"u", "v"}
        assert all(r.status == "ok" for r in results.values())


class TestMakePool:
    def test_unknown_kind_lists_valid_kinds(self):
        with pytest.raises(ValueError) as ei:
            make_pool("bogus")
        msg = str(ei.value)
        for kind in ("inline", "thread", "process", "ssh", "slurm", "pbs"):
            assert kind in msg

    def test_ssh_requires_hosts(self):
        with pytest.raises(ValueError, match="hosts"):
            make_pool("ssh")

    def test_ssh_kind_constructs_pool(self):
        pool = make_pool("ssh", hosts=["a"], ppnode=3,
                         transport=LocalTransport(), render=render)
        try:
            assert pool.kind == "ssh" and pool.slots == 3
        finally:
            pool.shutdown()


class TestSSHTransportCommand:
    def test_remote_command_inlines_env_and_cwd(self):
        cmd = SSHTransport.remote_command(
            "run --x 1", {"A": "1", "B": "two words"}, "/work dir")
        assert cmd == ("export A=1; export B='two words'; "
                       "cd '/work dir' && run --x 1")
