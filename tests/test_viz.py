"""DOT emission: labels/ids with quotes and backslashes must render as
valid DOT (regression: unescaped characters broke the quoted tokens)."""
from repro.core import TaskDAG, TaskNode, to_dot


def _dag_with_hostile_names() -> TaskDAG:
    dag = TaskDAG()
    dag.add(TaskNode(id='t"a@c\\1', task='t"a', combo={}))
    dag.add(TaskNode(id='t2@c\\1', task="t2", combo={},
                     deps=['t"a@c\\1']))
    return dag


class TestDotEscaping:
    def test_quotes_and_backslashes_escaped(self):
        out = to_dot(_dag_with_hostile_names(), title='stu"dy\\x')
        # the hostile id must appear only in escaped form
        assert '"t\\"a@c\\\\1"' in out
        assert '"stu\\"dy\\\\x"' in out
        # edge statement uses the escaped ids on both ends
        assert '"t\\"a@c\\\\1" -> "t2@c\\\\1";' in out

    def test_every_quoted_token_is_balanced(self):
        """Crude DOT well-formedness: stripping escaped sequences must
        leave an even number of quotes on every line."""
        out = to_dot(_dag_with_hostile_names(), title='q"t')
        for line in out.splitlines():
            bare = line.replace("\\\\", "").replace('\\"', "")
            assert bare.count('"') % 2 == 0, line

    def test_label_contains_escaped_task(self):
        out = to_dot(_dag_with_hostile_names())
        assert 'label="t\\"a\\nt\\"a@c\\\\1"' in out

    def test_clean_names_unchanged(self):
        dag = TaskDAG()
        dag.add(TaskNode(id="t@c1", task="t", combo={}))
        out = to_dot(dag, title="papas_study")
        assert 'digraph "papas_study" {' in out
        assert '"t@c1" [label="t\\nt@c1", fillcolor=gray];' in out
