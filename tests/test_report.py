"""Report layer: pivoting, rendering, offline records aggregation, and
the live-vs-offline reproducibility contract."""
import json

import pytest

from repro.core import ParameterStudy, ResultsAggregator, parse_yaml
from repro.launch.report import (
    aggregate_records, iter_records, main, parse_baseline, pivot_rows,
    render_rows, run_report, speedup_report, summary_report, table_report,
)


def _agg() -> ResultsAggregator:
    agg = ResultsAggregator(["size", "threads"])
    for size in (16, 32):
        for p in (1, 2, 4):
            for rep in range(2):
                agg.add({"args:size": size},
                        {"threads": p, "time": size / p + rep * 0.0})
    return agg


class TestRendering:
    def test_markdown_shape(self):
        out = render_rows(["a", "b"], [[1, 2.5], ["x", None]], "md")
        lines = out.splitlines()
        assert lines[0].startswith("| a") and "| b" in lines[0]
        assert set(lines[1]) <= {"|", "-"}
        assert "| 2.5" in lines[2] and lines[3].count("|") == 3

    def test_csv_and_json(self):
        out = render_rows(["a", "b"], [[1, None]], "csv")
        assert out == "a,b\n1,"
        doc = json.loads(render_rows(["a", "b"], [[1, None]], "json"))
        assert doc == [{"a": 1, "b": None}]

    def test_unknown_format(self):
        with pytest.raises(ValueError, match="unknown format"):
            render_rows(["a"], [], "xml")

    def test_pivot_two_axes(self):
        entries = {(16, 1): 1.0, (16, 2): 0.5, (32, 1): 2.0}
        headers, rows = pivot_rows(entries, ["size", "threads"])
        assert headers == ["size", "threads=1", "threads=2"]
        assert rows == [[16, 1.0, 0.5], [32, 2.0, None]]

    def test_pivot_single_axis(self):
        headers, rows = pivot_rows({(2,): 0.5, (1,): 1.0}, ["threads"])
        assert headers == ["threads", "value"]
        assert rows == [[1, 1.0], [2, 0.5]]


class TestReports:
    def test_summary_contains_all_stats(self):
        out = summary_report(_agg(), "time")
        assert "count" in out and "median" in out
        assert "| 16" in out

    def test_table_pivots_mean(self):
        out = table_report(_agg(), "time", "mean")
        assert "threads=4" in out
        # size=32, threads=4 → 8
        row = [l for l in out.splitlines() if l.startswith("| 32")][0]
        assert "| 8" in row

    def test_speedup_report_values(self):
        out = speedup_report(_agg(), "time", {"threads": 1})
        assert "# speedup of mean(time), baseline threads=1" in out
        assert "# efficiency of mean(time), baseline threads=1" in out
        doc = json.loads(speedup_report(_agg(), "time", {"threads": 1},
                                        fmt="json"))
        by_key = {(d["size"], d["threads"]): d for d in doc}
        assert by_key[(16, 4)]["speedup"] == pytest.approx(4.0)
        assert by_key[(16, 4)]["efficiency"] == pytest.approx(1.0)

    def test_run_report_dispatch_and_errors(self):
        agg = _agg()
        assert "count" in run_report(agg, "summary", "time")
        with pytest.raises(ValueError, match="baseline"):
            run_report(agg, "speedup", "time")
        with pytest.raises(ValueError, match="unknown report"):
            run_report(agg, "nope", "time")

    def test_parse_baseline(self):
        assert parse_baseline("threads=1") == {"threads": 1}
        assert parse_baseline("mode=fast") == {"mode": "fast"}
        with pytest.raises(ValueError):
            parse_baseline("threads")


WDL = """
t:
  x: ["1:4"]
  command: noop
  capture:
    v: "v=([0-9]+)"
"""


def _finished_study(tmp_path, name="rep"):
    study = ParameterStudy(parse_yaml(WDL), root=tmp_path, name=name)
    study.registry.update({"t": lambda combo: f"v={combo['x']}"})
    return study


class TestOfflineRecords:
    def test_offline_reproduces_live(self, tmp_path):
        study = _finished_study(tmp_path)
        live = ResultsAggregator(["x"])
        study.run(aggregator=live, keep_results=False)
        offline = aggregate_records(study.db.dir, ["x"])
        assert offline.n_grouped == live.n_grouped == 4
        assert table_report(offline, "v") == table_report(live, "v")

    def test_latest_ok_record_wins(self, tmp_path):
        study = _finished_study(tmp_path)
        study.run()
        # a re-run without resume appends duplicate ok records; the
        # offline reader must count each instance once, latest wins
        study2 = _finished_study(tmp_path)
        study2.registry.update({"t": lambda combo: f"v={combo['x'] + 10}"})
        study2.run()
        agg = aggregate_records(study2.db.dir, ["x"])
        assert agg.n_grouped == 4
        assert sorted(k for (k,) in agg.groups) == [1, 2, 3, 4]
        assert agg.groups[(1,)]["v"].mean == 11

    def test_records_path_accepts_dir_and_file(self, tmp_path):
        study = _finished_study(tmp_path)
        study.run()
        via_dir = list(iter_records(study.db.dir))
        via_file = list(iter_records(study.db.dir / "records.jsonl"))
        assert via_dir == via_file and len(via_dir) == 4

    def test_missing_records_raises(self, tmp_path):
        with pytest.raises(FileNotFoundError):
            aggregate_records(tmp_path, ["x"])


class TestCLI:
    def test_main_ok(self, tmp_path, capsys):
        study = _finished_study(tmp_path)
        study.run()
        rc = main([str(study.db.dir), "--group-by", "x",
                   "--metric", "v", "--report", "table"])
        out = capsys.readouterr().out
        assert rc == 0 and "| x" in out

    def test_main_speedup_needs_baseline(self, tmp_path, capsys):
        study = _finished_study(tmp_path)
        study.run()
        rc = main([str(study.db.dir), "--group-by", "x",
                   "--metric", "v", "--report", "speedup"])
        assert rc == 2
        assert "baseline" in capsys.readouterr().err

    def test_main_bad_path(self, tmp_path, capsys):
        rc = main([str(tmp_path / "nope"), "--group-by", "x"])
        assert rc == 2
        assert "error" in capsys.readouterr().err

    def test_main_unmatched_group_key(self, tmp_path, capsys):
        study = _finished_study(tmp_path)
        study.run()
        rc = main([str(study.db.dir), "--group-by", "nothere",
                   "--metric", "v"])
        assert rc == 2
        assert "no records matched" in capsys.readouterr().err
