"""Workflow engine tests: DAG, scheduling, fault isolation, simulation."""
import pytest

from repro.core import (
    DAGError, ScheduleEvent, Scheduler, TaskDAG, TaskNode, dispatch_count,
    makespan,
)


def chain(n):
    dag = TaskDAG()
    for i in range(n):
        dag.add(TaskNode(id=f"t{i}", task="t", combo={},
                         deps=[f"t{i-1}"] if i else []))
    return dag


def independent(n):
    dag = TaskDAG()
    for i in range(n):
        dag.add(TaskNode(id=f"j{i:02d}", task="j", combo={}))
    return dag


class TestDAG:
    def test_topological_respects_deps(self):
        dag = chain(5)
        order = [n.id for n in dag.topological()]
        assert order == [f"t{i}" for i in range(5)]

    def test_cycle_detected(self):
        dag = TaskDAG()
        dag.add(TaskNode(id="a", task="t", combo={}, deps=["b"]))
        dag.add(TaskNode(id="b", task="t", combo={}, deps=["a"]))
        with pytest.raises(DAGError):
            list(dag.topological())

    def test_missing_dep_detected(self):
        dag = TaskDAG()
        dag.add(TaskNode(id="a", task="t", combo={}, deps=["ghost"]))
        with pytest.raises(DAGError):
            dag.validate()

    def test_duplicate_id_rejected(self):
        dag = TaskDAG()
        dag.add(TaskNode(id="a", task="t", combo={}))
        with pytest.raises(DAGError):
            dag.add(TaskNode(id="a", task="t", combo={}))

    def test_levels(self):
        dag = TaskDAG()
        dag.add(TaskNode(id="a", task="t", combo={}))
        dag.add(TaskNode(id="b", task="t", combo={}))
        dag.add(TaskNode(id="c", task="t", combo={}, deps=["a", "b"]))
        levels = dag.levels()
        assert sorted(levels[0]) == ["a", "b"]
        assert levels[1] == ["c"]


class TestExecution:
    def test_runs_everything(self):
        dag = independent(7)
        ran = []
        res = Scheduler().execute(dag, lambda n: ran.append(n.id))
        assert len(ran) == 7
        assert all(r.status == "ok" for r in res.values())

    def test_retry_then_success(self):
        dag = independent(1)
        attempts = {"n": 0}

        def flaky(node):
            attempts["n"] += 1
            if attempts["n"] == 1:
                raise RuntimeError("transient")
            return "ok"

        res = Scheduler(max_retries=2).execute(dag, flaky)
        r = res["j00"]
        assert r.status == "ok" and r.attempts == 2

    def test_failure_skips_dependents_only(self):
        dag = TaskDAG()
        dag.add(TaskNode(id="bad", task="t", combo={}))
        dag.add(TaskNode(id="child", task="t", combo={}, deps=["bad"]))
        dag.add(TaskNode(id="other", task="t", combo={}))

        def runner(node):
            if node.id == "bad":
                raise RuntimeError("boom")
            return 1

        res = Scheduler(max_retries=0).execute(dag, runner)
        assert res["bad"].status == "failed"
        assert res["child"].status == "skipped"
        assert res["other"].status == "ok"

    def test_checkpoint_restart_skips_completed(self):
        dag = chain(4)
        ran = []
        res = Scheduler().execute(dag, lambda n: ran.append(n.id),
                                  completed={"t0", "t1"})
        assert ran == ["t2", "t3"]
        assert res["t0"].attempts == 0  # restored, not re-run


class TestSimulation:
    """Reproduces the paper's Fig. 1 schedule-regime ordering."""

    def setup_method(self):
        self.dag = independent(25)
        self.durations = {f"j{i:02d}": 30.0 for i in range(25)}

    def test_optimal_all_parallel(self):
        ev = Scheduler().simulate(self.dag, self.durations, "optimal")
        assert makespan(ev) == pytest.approx(30.0)
        assert all(e.start == 0.0 for e in ev)

    def test_serial_back_to_back(self):
        ev = Scheduler().simulate(self.dag, self.durations, "serial")
        assert makespan(ev) == pytest.approx(25 * 30.0)

    def test_grouped_between_serial_and_optimal(self):
        sched = Scheduler(slots=4)
        grouped = makespan(sched.simulate(self.dag, self.durations,
                                          "grouped"))
        assert grouped == pytest.approx((25 / 4 + 1) // 1 * 30.0, abs=31)
        assert 30.0 < grouped < 25 * 30.0

    def test_common_worse_than_grouped(self):
        # multi-tenant jitter makes "common" strictly slower than PaPaS
        # grouped dispatch at equal slot count — the paper's core claim
        sched = Scheduler(slots=4)
        grouped = makespan(sched.simulate(self.dag, self.durations,
                                          "grouped"))
        common = makespan(sched.simulate(self.dag, self.durations,
                                         "common", queue_delay=5.0))
        assert common > grouped

    def test_dependencies_respected_in_sim(self):
        dag = chain(3)
        ev = Scheduler(slots=3).simulate(dag, {f"t{i}": 10.0
                                               for i in range(3)}, "optimal")
        by_id = {e.id: e for e in ev}
        assert by_id["t1"].start >= by_id["t0"].stop
        assert by_id["t2"].start >= by_id["t1"].stop

    def test_dispatch_count(self):
        ev = Scheduler(slots=4).simulate(self.dag, self.durations, "grouped")
        assert dispatch_count(ev) == 25
