"""System-level behaviour: the paper's end-to-end claims."""
import jax
import numpy as np

from repro.core import (
    GangExecutor, ParameterStudy, Scheduler, parse_yaml, stackable_key,
    makespan, dispatch_count,
)


def test_paper_claim_88_workflows():
    """§7: the matmul study = 88 independent executions."""
    spec = parse_yaml("""
matmulOMP:
  environ:
    OMP_NUM_THREADS: ["1:8"]
  args:
    size: ["16:*2:16384"]
  command: matmul ${args:size} out_${args:size}.txt
""")
    study = ParameterStudy(spec, root="/tmp/papas_sys", name="claim88")
    assert len(study.instances()) == 88


def test_paper_claim_grouping_beats_scheduler():
    """§6/Figs 3-4: grouped dispatch beats scheduler-managed submission
    at equal node counts, and dispatch count collapses."""
    from repro.core import TaskDAG, TaskNode
    dag = TaskDAG()
    for i in range(25):
        dag.add(TaskNode(id=f"j{i}", task="t", combo={}))
    dur = {f"j{i}": 1800.0 for i in range(25)}
    sched = Scheduler(slots=4)
    grouped = makespan(sched.simulate(dag, dur, "grouped"))
    common = makespan(sched.simulate(dag, dur, "common", queue_delay=120.0))
    assert grouped < common
    # real gang executor: one dispatch for the whole level
    spec = parse_yaml("""
t:
  args:
    x: ["1:25"]
  command: unused
""")
    study = ParameterStudy(spec, registry={"t": lambda c: c["args:x"]},
                           root="/tmp/papas_sys", name="gang25")
    gang = GangExecutor(stackable_key,
                        lambda nodes: [n.combo["args:x"] for n in nodes])
    res = study.run(gang=gang)
    assert len(res) == 25 and gang.stats.dispatches == 1


def test_study_of_training_runs_end_to_end(tmp_path):
    """A WDL hyperparameter study over the framework's own trainer,
    vmap-stack gang-packed: the full PaPaS-on-TPU loop."""
    from repro.train.ensemble import train_ensemble
    spec = parse_yaml("""
lr_sweep:
  args:
    lr: [0.001, 0.002]
    seed: ["0:1"]
    arch: [gemma3-1b]
    steps: [3]
    batch: [2]
    seq: [16]
  command: train
""")
    study = ParameterStudy(spec, root=tmp_path, name="lr")
    gang = GangExecutor(
        stackable_key,
        lambda nodes: train_ensemble([dict(n.combo) for n in nodes]))
    res = study.run(gang=gang)
    assert len(res) == 4
    assert gang.stats.dispatches == 1
    losses = [r.value for r in res.values()]
    assert all(np.isfinite(v) for v in losses)
