"""Windowed (streaming) admission through the unified engine.

``run(window=N)`` / ``Scheduler.execute(source=…, window=N)`` keep live
graph state O(slots + window) while preserving every eager-path
semantic: retries, failure closure, timeouts, resume, and backend
pluggability.  The acceptance bound — peak live ``TaskNode`` count ≤
``slots + window`` for a 10^5-combination study — is asserted here.
"""
import json

import pytest

from repro.core import (
    InstanceWindow, LocalTransport, ParameterStudy, Scheduler, TaskDAG,
    parse_yaml,
)

SMALL = """
work:
  args:
    x: [1, 2, 3]
    y: [10, 20]
  command: echo ${args:x} ${args:y}
"""

CHAIN = """
prep:
  args:
    x: [1, 2, 3, 4]
  command: echo p
train:
  after: [prep]
  command: echo t
"""

HUGE = """
t:
  args:
    a: ["1:100"]
    b: ["1:100"]
    c: ["1:10"]
  command: run ${args:a}
"""


class TestWindowedStudy:
    def test_matches_eager_results(self, tmp_path):
        runner = {"work": lambda c: c["args:x"] * c["args:y"]}
        eager = ParameterStudy(parse_yaml(SMALL), registry=runner,
                               root=tmp_path, name="eager")
        windowed = ParameterStudy(parse_yaml(SMALL), registry=runner,
                                  root=tmp_path, name="windowed")
        res_e = eager.run()
        res_w = windowed.run(window=2)
        assert set(res_e) == set(res_w)
        assert {k: r.value for k, r in res_e.items()} \
            == {k: r.value for k, r in res_w.items()}
        assert all(r.status == "ok" for r in res_w.values())

    def test_failure_closure_within_instance(self, tmp_path):
        def prep(c):
            if c["args:x"] == 3:
                raise RuntimeError("boom")
            return 0

        study = ParameterStudy(
            parse_yaml(CHAIN),
            registry={"prep": prep, "train": lambda c: 1},
            root=tmp_path, name="closure")
        res = study.run(window=2, max_retries=0)
        by_status = {}
        for r in res.values():
            by_status.setdefault(r.status, []).append(r.id)
        assert len(by_status["ok"]) == 6       # 3 instances × 2 tasks
        assert len(by_status["failed"]) == 1   # the poisoned prep
        assert len(by_status["skipped"]) == 1  # its dependent train

    def test_retries_apply(self, tmp_path):
        fails = {"n": 0}

        def flaky(c):
            if c["args:x"] == 2 and fails["n"] == 0:
                fails["n"] += 1
                raise RuntimeError("first attempt dies")
            return 0

        study = ParameterStudy(parse_yaml(SMALL), registry={"work": flaky},
                               root=tmp_path, name="retry")
        res = study.run(window=1, max_retries=1)
        assert all(r.status == "ok" for r in res.values())
        assert max(r.attempts for r in res.values()) == 2

    def test_result_streaming_skips_accumulation(self, tmp_path):
        """``on_result`` + ``keep_results=False``: every completion
        streams through the callback, nothing accumulates, and the
        journal still records everything."""
        seen = []
        study = ParameterStudy(parse_yaml(SMALL),
                               registry={"work": lambda c: c["args:x"]},
                               root=tmp_path, name="stream")
        res = study.run(window=2, on_result=lambda r: seen.append(r),
                        keep_results=False)
        assert res == {}                        # no O(N_W) result dict
        assert len(seen) == 6
        assert all(r.status == "ok" for r in seen)
        assert sorted(r.value for r in seen) == [1, 1, 2, 2, 3, 3]
        state = study.journal.load_state()
        assert len(state.completed_indices["work"]) == 6

    def test_on_result_streams_in_eager_mode_too(self, tmp_path):
        seen = []
        study = ParameterStudy(parse_yaml(SMALL),
                               registry={"work": lambda c: 0},
                               root=tmp_path, name="stream_eager")
        res = study.run(on_result=lambda r: seen.append(r.id))
        assert len(seen) == 6 and len(res) == 6
        assert set(seen) == set(res)

    def test_journal_is_v2_and_compact(self, tmp_path):
        study = ParameterStudy(parse_yaml(SMALL),
                               registry={"work": lambda c: 0},
                               root=tmp_path, name="j2")
        study.run(window=2)
        doc = json.loads(study.journal.path.read_text())
        assert doc["version"] == 2
        assert "instances" not in doc
        assert doc["n_instances"] == 6
        assert doc["completed"]["work"] == [[0, 5]]  # one folded range

    def test_resume_skips_without_admitting(self, tmp_path):
        study = ParameterStudy(parse_yaml(SMALL),
                               registry={"work": lambda c: 0},
                               root=tmp_path, name="skip")
        study.run(window=2)
        again = ParameterStudy(parse_yaml(SMALL), root=tmp_path, name="skip")
        ran = []
        again.run(window=2, resume=True,
                  runner=lambda n: ran.append(n.id) or 0)
        assert ran == []
        assert again.last_run_stats["admitted_instances"] == 0
        assert again.last_run_stats["skipped_complete"] == 6

    def test_window_smaller_than_slots_still_completes(self, tmp_path):
        study = ParameterStudy(parse_yaml(SMALL),
                               registry={"work": lambda c: 0},
                               root=tmp_path, name="tiny")
        res = study.run(window=1, slots=4, pool="thread")
        assert len(res) == 6
        assert all(r.status == "ok" for r in res.values())

    def test_thread_pool_windowed(self, tmp_path):
        study = ParameterStudy(parse_yaml(SMALL),
                               registry={"work": lambda c: 0},
                               root=tmp_path, name="thr")
        res = study.run(window=3, slots=2, pool="thread")
        assert all(r.status == "ok" for r in res.values())
        assert study.last_run_stats["peak_live_nodes"] <= 2 + 3

    def test_ssh_pool_windowed_records_hosts(self, tmp_path):
        study = ParameterStudy(parse_yaml("""
sh:
  args:
    n: [1, 2, 3, 4]
  command: echo v-${args:n}
"""), root=tmp_path, name="sshw")
        res = study.run(window=2, pool="ssh", hosts=["h0", "h1"], ppnode=1,
                        transport=LocalTransport())
        assert all(r.status == "ok" for r in res.values())
        assert len(study.journal.hosts()) == 4
        assert set(study.journal.hosts().values()) <= {"h0", "h1"}


class TestAdmissionBound:
    def test_peak_live_nodes_at_1e5_combos(self, tmp_path):
        """Acceptance: a 10^5-combination study completes with peak live
        TaskNode count ≤ slots + window (raw engine: no journal I/O, so
        the bound — not disk throughput — is what's under test)."""
        study = ParameterStudy(parse_yaml(HUGE), root=tmp_path, name="huge")
        assert study.instance_count() == 100_000
        source = InstanceWindow(study)
        sched = Scheduler(slots=4)
        res = sched.execute(TaskDAG(), lambda n: 0,
                            source=source, window=16)
        assert len(res) == 100_000
        assert all(r.status == "ok" for r in res.values())
        assert sched.peak_live_nodes <= 4 + 16

    def test_multi_task_instances_respect_bound(self, tmp_path):
        study = ParameterStudy(
            parse_yaml(CHAIN),
            registry={"prep": lambda c: 0, "train": lambda c: 0},
            root=tmp_path, name="bound2")
        study.run(window=2, slots=2)
        # strict even though each instance admits 2 nodes at once: a
        # sub-DAG that would overflow the budget waits for retirements
        assert study.last_run_stats["peak_live_nodes"] <= 2 + 2

    def test_instance_larger_than_budget_still_runs(self, tmp_path):
        # progress guarantee: window + slots smaller than one instance's
        # sub-DAG admits the instance whole (the one allowed excursion)
        study = ParameterStudy(
            parse_yaml(CHAIN),
            registry={"prep": lambda c: 0, "train": lambda c: 0},
            root=tmp_path, name="over")
        res = study.run(window=1, slots=1)
        assert len(res) == 8
        assert all(r.status == "ok" for r in res.values())
        assert study.last_run_stats["peak_live_nodes"] == 2  # one instance

    def test_source_and_window_must_pair(self):
        sched = Scheduler(slots=1)
        with pytest.raises(ValueError):
            sched.execute(TaskDAG(), lambda n: 0, window=4)
        with pytest.raises(ValueError):
            sched.execute(TaskDAG(), lambda n: 0, source=object())

    def test_window_must_be_positive(self, tmp_path):
        study = ParameterStudy(parse_yaml(SMALL),
                               registry={"work": lambda c: 0},
                               root=tmp_path, name="w0")
        with pytest.raises(ValueError):
            study.run(window=0)

    def test_eager_path_unchanged_by_default(self, tmp_path):
        study = ParameterStudy(parse_yaml(SMALL),
                               registry={"work": lambda c: 0},
                               root=tmp_path, name="eag")
        res = study.run()
        assert all(r.status == "ok" for r in res.values())
        doc = json.loads(study.journal.path.read_text())
        assert doc["version"] == 1 and len(doc["instances"]) == 6


class TestSampledStreaming:
    def test_windowed_run_respects_sampling(self, tmp_path):
        study = ParameterStudy(parse_yaml("""
work:
  args:
    x: ["1:100"]
  sampling:
    method: random
    count: 10
    seed: 7
  command: echo ${args:x}
"""), registry={"work": lambda c: 0}, root=tmp_path, name="sampled")
        res = study.run(window=4)
        assert len(res) == 10
        doc = json.loads(study.journal.path.read_text())
        n_done = sum(e - s + 1 for r in doc["completed"].values()
                     for s, e in r)
        assert n_done == 10
