"""Batched serving demo: continuous token-level batching (slots).

    PYTHONPATH=src python examples/serve_demo.py
"""
import jax

from repro.configs import get_smoke
from repro.models import Model
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = get_smoke("gemma3-1b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, slots=4, max_len=64)

    for rid in range(8):
        engine.submit(Request(rid=rid, prompt=[1 + rid, 2, 3], max_new=8))
    done = engine.run()
    for req in sorted(done, key=lambda r: r.rid):
        print(f"req {req.rid}: prompt={req.prompt} -> {req.generated}")
    print(f"served {len(done)} requests on {engine.slots} slots")


if __name__ == "__main__":
    main()
