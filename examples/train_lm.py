"""End-to-end LM training example with checkpoint/restart.

    PYTHONPATH=src python examples/train_lm.py            # ~3M smoke model
    PYTHONPATH=src python examples/train_lm.py --large    # ~100M config

Wraps the production driver (repro.launch.train): sharded state, data
stream, jit'd step, periodic checkpoints; rerun the same command after a
kill to resume from the last checkpoint.
"""
import subprocess
import sys

LARGE = ["--arch", "gemma3-1b", "--steps", "300", "--batch", "8",
         "--seq", "512"]                       # ~1B full config
SMOKE = ["--arch", "gemma3-1b", "--smoke", "--steps", "200",
         "--batch", "8", "--seq", "64"]


def main():
    args = LARGE if "--large" in sys.argv else SMOKE
    cmd = [sys.executable, "-m", "repro.launch.train", *args,
           "--ckpt-dir", "/tmp/papas_train_lm", "--ckpt-every", "50"]
    print("+", " ".join(cmd))
    raise SystemExit(subprocess.call(cmd))


if __name__ == "__main__":
    main()
