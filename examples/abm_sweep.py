"""NetLogo-style ABM parameter sweep with fault-tolerant restart (§6).

    PYTHONPATH=src python examples/abm_sweep.py

Runs 25 agent-based-model instances; the first attempt is killed halfway
(simulated node failure), then resumed from the study journal — only the
incomplete instances re-run.  Finishes with the gang-dispatch comparison
(dispatch counts mirror the paper's Figs. 3/4 table).
"""
import numpy as np

from repro.core import (
    GangExecutor, ParameterStudy, parse_yaml, stackable_key,
)

WDL = """
abm:
  name: healthcare-transmission ABM sweep
  args:
    beta: [0.1, 0.2, 0.3, 0.4, 0.5]
    seed: ["0:4"]
  command: unused
"""


def abm(combo):
    rng = np.random.default_rng(int(combo["args:seed"]))
    beta = float(combo["args:beta"])
    grid = np.zeros((32, 32), np.int8)
    grid[16, 16] = 1
    for _ in range(60):
        inf = grid == 1
        nb = (np.roll(inf, 1, 0) | np.roll(inf, -1, 0)
              | np.roll(inf, 1, 1) | np.roll(inf, -1, 1))
        grid[(grid == 0) & nb & (rng.random((32, 32)) < beta)] = 1
        grid[inf & (rng.random((32, 32)) < 0.1)] = 2
    return float((grid == 2).sum())


def main():
    spec = parse_yaml(WDL)

    # --- first attempt dies after 12 tasks (node failure) -------------
    count = {"n": 0}

    def flaky(combo):
        if count["n"] >= 12:
            raise RuntimeError("node failure")
        count["n"] += 1
        return abm(combo)

    s1 = ParameterStudy(spec, registry={"abm": flaky},
                        root="/tmp/papas_abm", name="abm")
    r1 = s1.run(max_retries=0)
    done = sum(1 for r in r1.values() if r.status == "ok")
    print(f"attempt 1: {done}/25 complete before failure")

    # --- restart: journal resumes exactly the missing instances -------
    s2 = ParameterStudy(spec, registry={"abm": abm},
                        root="/tmp/papas_abm", name="abm")
    r2 = s2.run(resume=True)
    print(f"attempt 2 (resumed): "
          f"{sum(1 for r in r2.values() if r.status == 'ok')}/25 complete")

    # --- gang dispatch: 25 tasks, 1 launch -----------------------------
    s3 = ParameterStudy(spec, registry={"abm": abm},
                        root="/tmp/papas_abm", name="abm_gang")
    gang = GangExecutor(stackable_key,
                        lambda nodes: [abm(n.combo) for n in nodes])
    s3.run(gang=gang)
    print(f"gang dispatch: {gang.stats.tasks} tasks in "
          f"{gang.stats.dispatches} dispatch (batching x"
          f"{gang.stats.batching_factor:.0f})")


if __name__ == "__main__":
    main()
