"""Quickstart: the paper's Fig. 5 matmul study, end to end.

    PYTHONPATH=src python examples/quickstart.py

Parses the paper's own WDL, expands the 88 workflow instances, runs them
through the study engine (with the task profiler), and prints the
provenance summary + a DAG preview.

    PYTHONPATH=src python examples/quickstart.py --pool ssh --hosts a,b

runs a reduced study through the SSH worker pool instead: each instance
becomes a *shell command* dispatched to a ``hosts × ppnode`` slot over
the no-network ``LocalTransport`` fake (commands execute locally, host
identity and slot accounting preserved) — the CI smoke for the paper's
distributed parallelization (§4.3).

    PYTHONPATH=src python examples/quickstart.py --window 64

runs a 16 000-combination study through the *streaming* pipeline:
instances are addressed by space index (never materialized), at most
``slots + window`` task nodes stay live, and the journal is compact v2 —
the smoke prints wall time, peak RSS, and the asserted live-node bound.

    PYTHONPATH=src python examples/quickstart.py --pool lane

runs the reduced shell study through *persistent worker lanes*: one
long-lived ``sh`` per slot fed rendered commands over a pipe protocol —
the short-task throughput path.  The smoke asserts per-attempt lane
provenance in records.jsonl (and that transient lane labels stay OUT of
the journal host map).

    PYTHONPATH=src python examples/quickstart.py --report

runs the paper's §6 performance-study shape (``examples/
matmul_perf.yaml``: threads × size over a stand-in compute with
``capture:`` extraction and a 1-thread ``baseline:``) through windowed
lanes with ``keep_results=False``, then *asserts* the streamed
speedup/efficiency pivot — the stand-in scales perfectly, so speedup
must equal the thread count — and that the offline report from
``records.jsonl`` reproduces the live table cell for cell.

    PYTHONPATH=src python examples/quickstart.py --chaos lane|host|sigkill

runs the deterministic fault-injection smokes (``repro.core.chaos`` +
the canned plans in ``examples/chaos/``): lane-worker kills retried to
a byte-identical record set, host failures quarantined and *recovered*
through probation, and a mid-run SIGKILL + torn journal segment that
resume must replay exactly — the CI chaos gate runs all three.

    PYTHONPATH=src python examples/quickstart.py --trace --status

runs the telemetry smoke (``repro.core.telemetry``): a chaos-armed
windowed lane study with the trace collector, the ``/metrics`` HTTP
endpoint, and the live status line armed.  The smoke asserts the
written ``trace.json`` is schema-valid Chrome trace-event JSON (every
``B`` closed, per-track stack discipline), that its dispatch spans
cover every completed instance, that the ``study.json`` counter
snapshot matches the results, and that ``/metrics`` reports nonzero
retry + fault counters — then prints where to load the trace
(https://ui.perfetto.dev).
"""
import argparse
import resource
import time
from pathlib import Path

import numpy as np

from repro.core import (LocalTransport, ParameterStudy, ResultsAggregator,
                        load_study, parse_yaml)

WDL = """
matmulOMP:
  name: Matrix multiply scaling study with OpenMP
  environ:
    OMP_NUM_THREADS: ["1:8"]
  args:
    size: ["16:*2:16384"]
  command: matmul ${args:size} result_${args:size}N_${environ:OMP_NUM_THREADS}T.txt
"""


def matmul(combo):
    n = min(int(combo["args:size"]), 512)      # cap for the demo box
    a = np.ones((n, n), np.float32)
    return float((a @ a)[0, 0])


# remote smoke: same study shape, reduced size, pure shell commands
# (registry callables cannot be shipped to a remote host)
REMOTE_WDL = """
matmulOMP:
  name: Matrix multiply scaling study over SSH slots
  environ:
    OMP_NUM_THREADS: ["1:2"]
  args:
    size: ["16:*2:64"]
  command: echo ${args:size}N_${environ:OMP_NUM_THREADS}T
"""


def run_lane(slots: int = 2) -> None:
    """Lane-pool smoke: the reduced shell study through persistent
    worker lanes, with lane-host provenance and batching asserted."""
    study = ParameterStudy(parse_yaml(REMOTE_WDL),
                           root="/tmp/papas_quickstart",
                           name="quickstart_lane")
    results = study.run(pool="lane", slots=slots)
    ok = sum(1 for r in results.values() if r.status == "ok")
    by_lane: dict = {}
    for r in results.values():
        by_lane[r.host] = by_lane.get(r.host, 0) + 1
    print(f"[lane] completed {ok}/{len(results)} across lanes {by_lane}")
    assert ok == len(results), "lane smoke: tasks failed"
    # lane identity is per-attempt provenance: in records.jsonl, but
    # NOT in the journal host map (which stays O(remote tasks))
    rec_hosts = {r["task_id"]: r["host"] for r in study.db.records()}
    assert len(rec_hosts) == len(results) and all(
        h.startswith("lane") for h in rec_hosts.values()), \
        "lane smoke: records missing per-attempt lane provenance"
    assert study.journal.hosts() == {}, \
        "lane smoke: transient lane labels leaked into the journal"
    print(f"[lane] records carry lanes for {len(rec_hosts)} attempts")


def run_remote(hosts: str, ppnode: int) -> None:
    study = ParameterStudy(parse_yaml(REMOTE_WDL),
                           root="/tmp/papas_quickstart",
                           name="quickstart_ssh")
    results = study.run(pool="ssh",
                        hosts=[h for h in hosts.split(",") if h],
                        ppnode=ppnode, transport=LocalTransport())
    ok = sum(1 for r in results.values() if r.status == "ok")
    by_host: dict = {}
    for r in results.values():
        by_host[r.host] = by_host.get(r.host, 0) + 1
    print(f"[ssh] completed {ok}/{len(results)} across hosts {by_host}")
    journal_hosts = study.journal.hosts()
    assert ok == len(results), "remote smoke: tasks failed"
    assert len(journal_hosts) == len(results), \
        "remote smoke: journal missing per-task hosts"
    print(f"[ssh] journal records hosts for {len(journal_hosts)} tasks")


# streaming smoke: a 40 × 40 × 10 = 16 000-combination space, run with a
# bounded admission window — large enough that eager materialization
# would dominate startup, small enough for a CI gate
WINDOW_WDL = """
sweep:
  args:
    a: ["1:40"]
    b: ["1:40"]
    c: ["1:10"]
  command: noop ${args:a} ${args:b} ${args:c}
"""


def run_windowed(window: int, slots: int = 4) -> None:
    study = ParameterStudy(parse_yaml(WINDOW_WDL),
                           registry={"sweep": lambda combo: 0},
                           root="/tmp/papas_quickstart",
                           name=f"quickstart_window{window}")
    n = study.instance_count()
    t0 = time.perf_counter()
    results = study.run(window=window, slots=slots)
    wall = time.perf_counter() - t0
    ok = sum(1 for r in results.values() if r.status == "ok")
    stats = study.last_run_stats
    rss_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024
    print(f"[window] {ok}/{n} instances in {wall:.1f}s "
          f"({n / max(wall, 1e-9):.0f} tasks/s), peak RSS {rss_mb:.0f} MB")
    print(f"[window] peak live nodes {stats['peak_live_nodes']} "
          f"(bound: slots + window = {slots + window})")
    assert ok == n, "windowed smoke: tasks failed"
    assert stats["peak_live_nodes"] <= slots + window, \
        "windowed smoke: admission window exceeded"
    import json
    doc = json.loads(study.journal.path.read_text())
    assert doc["version"] == 2 and "instances" not in doc, \
        "windowed smoke: expected compact v2 journal"
    print(f"[window] journal v2: {study.journal.path.stat().st_size} bytes "
          f"for {n} instances (completed ranges: {doc['completed']})")


def run_perf_report(window: int = 16, slots: int = 2) -> None:
    """Performance-study smoke: matmul_perf.yaml streamed through
    windowed lanes, speedup table asserted against the stand-in's
    perfect scaling, offline report asserted equal to the live one."""
    from repro.launch.report import aggregate_records, speedup_report

    study = load_study(Path(__file__).parent / "matmul_perf.yaml",
                       root="/tmp/papas_quickstart", name="quickstart_perf")
    agg = ResultsAggregator(["size", "threads"])
    study.run(pool="lane", slots=slots, window=window, keep_results=False,
              aggregator=agg)
    baseline = {"threads": 1}
    derived = agg.speedup("time", baseline)
    n = study.instance_count()
    assert agg.n_grouped == n, \
        f"report smoke: {agg.n_grouped}/{n} instances aggregated"
    for (size, threads), vals in derived.items():
        assert vals["speedup"] is not None and \
            abs(vals["speedup"] - threads) < 0.05 * threads, \
            f"report smoke: speedup at {size}x{threads} = {vals['speedup']}"
        assert abs(vals["efficiency"] - 1.0) < 0.05, \
            f"report smoke: efficiency at {size}x{threads} " \
            f"= {vals['efficiency']}"
    live = speedup_report(agg, "time", baseline)
    offline_agg = aggregate_records(study.db.dir, ["size", "threads"])
    offline = speedup_report(offline_agg, "time", baseline)
    assert live == offline, \
        "report smoke: offline records.jsonl table diverges from live"
    print(live)
    print(f"[report] speedup == threads for all {len(derived)} groups; "
          f"offline table reproduces the live one")


# -- chaos smokes ----------------------------------------------------------
# deterministic fault injection (repro.core.chaos): each smoke loads a
# canned plan from examples/chaos/, injects the faults through a real
# backend seam, and asserts the engine's recovery invariant — the
# surviving record set is byte-identical to a fault-free run's
# (record_fingerprint), or the lost capacity is reported as degraded.

CHAOS_DIR = Path(__file__).parent / "chaos"
CHAOS_ROOT = Path("/tmp/papas_quickstart")


def _fresh_study(name: str, **kwargs) -> ParameterStudy:
    """The reduced shell study under a wiped per-smoke directory."""
    import shutil
    shutil.rmtree(CHAOS_ROOT / name, ignore_errors=True)
    return ParameterStudy(parse_yaml(REMOTE_WDL), root=CHAOS_ROOT,
                          name=name, **kwargs)


def run_chaos_lane(slots: int = 2) -> None:
    """Lane-kill chaos smoke: run the study clean, then under a
    kill_lane plan with retry backoff — every injected death must be
    retried to success and the record sets must match byte for byte."""
    from repro.core import FaultPlan, record_fingerprint

    plan = FaultPlan.load(CHAOS_DIR / "lane_kill.yaml")
    clean = _fresh_study("chaos_lane_clean")
    clean.run(pool="lane", slots=slots)
    fp_clean = record_fingerprint(clean.db.records())

    faulty = _fresh_study("chaos_lane")
    ctrl = plan.controller()
    results = faulty.run(pool="lane", slots=slots, chaos=ctrl,
                         max_retries=3, retry={"base": 0.01})
    assert len(ctrl.ledger) >= 1, "chaos:lane — plan injected nothing"
    assert all(r.status == "ok" for r in results.values()), \
        "chaos:lane — a killed task was not retried to success"
    fp = record_fingerprint(faulty.db.records())
    assert fp == fp_clean, \
        "chaos:lane — record set diverges from the fault-free run"
    meta = faulty.db.read_meta()
    assert meta.get("degraded") and meta.get("fault_ledger"), \
        "chaos:lane — study.json missing the degraded fault ledger"
    print(f"[chaos:lane] {len(ctrl.ledger)} lane kill(s) injected; "
          f"{len(results)} tasks recovered; record fingerprints match "
          f"({len(fp)} entries)")


def run_chaos_host() -> None:
    """Host-probation chaos smoke: 'flaky' refuses its first dispatches
    by plan, is quarantined with backoff, then answers its probe — it
    must recover and serve work again, never turn permanently dead."""
    from repro.core import (FaultPlan, LocalTransport, ShellResult,
                            SSHWorkerPool)

    plan = FaultPlan.load(CHAOS_DIR / "host_quarantine.yaml")
    study = _fresh_study("chaos_host")

    def hook(host, command):
        # the healthy host is deliberately slow, so the queue is still
        # live when "flaky" finishes probation and takes its probe
        time.sleep(0.08 if host == "ok" else 0.005)
        return ShellResult(0, host, "", 0)

    pool = SSHWorkerPool(["flaky", "ok"], ppnode=1,
                         transport=LocalTransport(hook=hook),
                         render=study.render_node, probation=0.05)
    ctrl = plan.controller()
    try:
        results = study.run(pool=pool, chaos=ctrl, max_retries=3)
    finally:
        pool.shutdown()
    assert all(r.status == "ok" for r in results.values()), \
        "chaos:host — tasks failed despite a recoverable host"
    assert "flaky" not in pool.dead_hosts, \
        "chaos:host — probation declared a recoverable host dead"
    assert len(ctrl.ledger) == 2, \
        f"chaos:host — expected 2 injected failures, got {len(ctrl.ledger)}"
    served = {r.host for r in results.values()}
    assert "flaky" in served, \
        f"chaos:host — recovered host served nothing (hosts: {served})"
    print(f"[chaos:host] flaky failed {len(ctrl.ledger)}x, was "
          f"quarantined, probed back, and served "
          f"{sum(1 for r in results.values() if r.host == 'flaky')} "
          f"task(s); dead_hosts={sorted(pool.dead_hosts) or '{}'}")


def run_chaos_child() -> None:
    """(internal) the SIGKILL smoke's victim: runs the crash study under
    the sigkill plan — by construction this process never returns."""
    from repro.core import FaultPlan

    plan = FaultPlan.load(CHAOS_DIR / "sigkill_resume.yaml")
    study = ParameterStudy(parse_yaml(REMOTE_WDL), root=CHAOS_ROOT,
                           name="chaos_crash",
                           flush_count=1, flush_interval=None)
    study.run(pool="lane", slots=2, chaos=plan)
    raise SystemExit("chaos child survived its own sigkill plan")


def run_chaos_sigkill() -> None:
    """Crash-resume chaos smoke: a child process is SIGKILLed mid-run
    by plan, a journal append segment's tail is torn (the crash shape),
    and resume must replay to the exact fault-free record set — then a
    second resume must be a no-op (idempotent)."""
    import os
    import subprocess
    import sys
    import warnings
    from repro.core import FaultPlan, record_fingerprint

    clean = _fresh_study("chaos_crash_clean")
    clean.run(pool="lane", slots=2)
    fp_clean = record_fingerprint(clean.db.records())

    _fresh_study("chaos_crash")         # wipe the crash directory
    proc = subprocess.run([sys.executable, __file__, "--chaos-child"],
                          env=os.environ.copy(), capture_output=True,
                          text=True, timeout=120)
    assert proc.returncode == -9, \
        (f"chaos:sigkill — child exited {proc.returncode}, expected "
         f"SIGKILL (-9); stderr:\n{proc.stderr}")
    print("[chaos:sigkill] child killed mid-run by plan (rc=-9)")

    plan = FaultPlan.load(CHAOS_DIR / "sigkill_resume.yaml")
    study = ParameterStudy(parse_yaml(REMOTE_WDL), root=CHAOS_ROOT,
                           name="chaos_crash",
                           flush_count=1, flush_interval=None)
    torn = plan.controller().apply_file_faults(study.db.dir)
    assert torn, "chaos:sigkill — no journal segment left to tear"
    print(f"[chaos:sigkill] tore segment tail: "
          f"{', '.join(p.name for p in torn)}")
    with warnings.catch_warnings():
        # the torn entry warns-and-drops by design
        warnings.simplefilter("ignore", RuntimeWarning)
        study.run(pool="lane", slots=2, resume=True)
        fp = record_fingerprint(study.db.records())
        assert fp == fp_clean, \
            "chaos:sigkill — resume diverged from the fault-free record set"
        n_recs = sum(1 for _ in study.db.records())
        again = ParameterStudy(parse_yaml(REMOTE_WDL), root=CHAOS_ROOT,
                               name="chaos_crash",
                               flush_count=1, flush_interval=None)
        again.run(pool="lane", slots=2, resume=True)
        assert sum(1 for _ in again.db.records()) == n_recs, \
            "chaos:sigkill — a second resume appended records (not idempotent)"
        assert record_fingerprint(again.db.records()) == fp_clean
    print(f"[chaos:sigkill] resume replayed to the pre-crash set "
          f"({len(fp)} records, fingerprints match); second resume "
          f"idempotent")


# telemetry smoke: enough instances that the kill_lane fault lands
# mid-stream and the status line gets several redraws.  Every task
# fails its first attempt (marker file + `false` — `exit` would kill
# the persistent lane shell) so the retry counters are deterministic,
# not a race against how fast the killed frame drained.
TRACE_MARKERS = "/tmp/papas_quickstart/trace_markers"
TRACE_WDL = """
trace:
  args:
    i: ["1:200"]
  command: "test -e %s/t${args:i} || { : > %s/t${args:i}; false; }"
""" % (TRACE_MARKERS, TRACE_MARKERS)


def run_trace(status: bool = False, slots: int = 2,
              window: int = 32) -> None:
    """Telemetry smoke: a chaos-armed windowed lane study with the
    trace collector, the ``/metrics`` endpoint, and (optionally) the
    live status line — asserts trace schema validity, span coverage,
    counter ground truth, and nonzero fault/retry counters."""
    import json as json_mod
    import shutil
    import urllib.request

    from repro.core import FaultEvent, FaultPlan, Telemetry

    shutil.rmtree(CHAOS_ROOT / "quickstart_trace", ignore_errors=True)
    shutil.rmtree(TRACE_MARKERS, ignore_errors=True)
    Path(TRACE_MARKERS).mkdir(parents=True)
    study = ParameterStudy(parse_yaml(TRACE_WDL), root=CHAOS_ROOT,
                           name="quickstart_trace")
    tel = Telemetry()
    port = tel.serve(0)
    if status:
        tel.attach_status()
    plan = FaultPlan([FaultEvent("kill_lane", lane=0, after=20)])
    results = study.run(
        pool="lane", slots=slots, window=window, trace=tel,
        chaos=plan.controller(), max_retries=3, retry={"base": 0.01},
        on_result=(lambda r: tel.tick()) if status else None)
    if status:
        tel.finish_status()
    n_ok = sum(1 for r in results.values() if r.status == "ok")
    assert n_ok == len(results) == 200, \
        f"trace smoke: {n_ok}/{len(results)} instances ok"

    # query /metrics while the server is still up: the injected fault
    # and the retries it forced must be visible as nonzero counters
    with urllib.request.urlopen(
            f"http://127.0.0.1:{port}/metrics", timeout=10) as resp:
        text = resp.read().decode()
    tel.close()

    def family_sum(prefix: str) -> float:
        return sum(float(ln.rsplit(" ", 1)[1]) for ln in text.splitlines()
                   if ln.startswith(prefix) and not ln.startswith("#"))

    assert family_sum("papas_faults_total") >= 1, \
        "trace smoke: fault counter empty despite an armed kill_lane plan"
    assert family_sum("papas_retries_total") >= 1, \
        "trace smoke: retry counter empty despite a lane kill"

    # trace.json: schema-valid Chrome trace events — every B closed,
    # per-track stack discipline intact
    trace_path = study.db.dir / "trace.json"
    doc = json_mod.loads(trace_path.read_text())
    events = doc["traceEvents"]
    depth: dict = {}
    for ev in events:
        if ev["ph"] == "B":
            depth[ev["tid"]] = depth.get(ev["tid"], 0) + 1
        elif ev["ph"] == "E":
            depth[ev["tid"]] = depth.get(ev["tid"], 0) - 1
            assert depth[ev["tid"]] >= 0, "trace smoke: E without B"
    assert all(d == 0 for d in depth.values()), \
        f"trace smoke: unclosed B spans per tid: {depth}"
    # dispatch spans cover every completed instance (>=: the killed
    # attempt is a span too)
    covered = sum(ev.get("args", {}).get("tasks", 0) for ev in events
                  if ev["ph"] == "B" and ev.get("cat") == "dispatch")
    assert covered >= n_ok, \
        f"trace smoke: spans cover {covered}/{n_ok} instances"
    snap = study.db.read_meta()["telemetry"]
    assert snap.get("papas_tasks_completed_total") == n_ok, \
        "trace smoke: completed counter diverges from the results"
    print(f"[trace] {n_ok} instances, {len(events)} trace events, "
          f"{covered} instance-dispatches spanned, "
          f"{family_sum('papas_faults_total'):.0f} fault(s), "
          f"{family_sum('papas_retries_total'):.0f} retry(s)")
    print(f"[trace] wrote {trace_path} — load it in "
          f"https://ui.perfetto.dev (one track per slot/lane/commit "
          f"segment; chaos firings are instant events)")


# lint smoke: a study seeded with one of every static-defect class the
# analyzer must catch — never runnable, only linted
BROKEN_WDL = """
prep:
  command: "gen --n ${args:sizee} > series.dat"
  args:
    size: ["16:*2:64"]
  after: [ghost]
  timeout: 3600
crunch:
  command: "crunch ${args:size}"
  after: [report]
  infiles:
    series: "series_${prep:args:size}.dat"
  capture:
    gflops:
      regex: "gflops=([0-9.]+)"
      source: "outfile:missing"
  baseline:
    size: 999
report:
  command: "report"
  after: [crunch]
"""

#: rule ids the broken study must trip (one per seeded defect class)
EXPECTED_BROKEN_RULES = {
    "E101",   # ${args:sizee} typo
    "E201",   # after: ghost
    "E202",   # crunch <-> report cycle
    "E301",   # parameterized infile with no producer
    "E403",   # capture reads undeclared outfile
    "E501",   # baseline key resolves to nothing at crunch
}


def run_lint() -> None:
    """Lint smoke: the clean example must produce zero findings, the
    seeded-defect study must trip every expected rule id — through the
    real CLI formatters (text and JSON), exercising the report path
    end to end."""
    import json as json_mod

    from repro.launch.lint import lint_file, render_json, render_text

    clean_path = Path(__file__).parent / "matmul_perf.yaml"
    clean = lint_file(clean_path)
    broken = _lint_broken()
    reports = {str(clean_path): clean, "<broken>": broken}
    print(render_text(reports))
    doc = json_mod.loads(render_json(reports))
    assert clean.ok and not clean.errors, \
        "lint smoke: the shipped example must lint clean"
    got = {f.rule for f in broken.findings}
    missing = EXPECTED_BROKEN_RULES - got
    assert not missing, f"lint smoke: rules not tripped: {sorted(missing)}"
    assert doc["ok"] is False and not doc["files"][str(clean_path)]["findings"], \
        "lint smoke: JSON report diverges from text verdicts"
    print(f"[lint] clean example clean; broken study tripped "
          f"{sorted(got & EXPECTED_BROKEN_RULES)}")


def _lint_broken():
    from repro.core.lint import lint as lint_spec

    return lint_spec(parse_yaml(BROKEN_WDL, validate=False))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pool", default="inline",
                    choices=("inline", "ssh", "lane"))
    ap.add_argument("--hosts", default="localhost")
    ap.add_argument("--ppnode", type=int, default=2)
    ap.add_argument("--window", type=int, default=None,
                    help="run the 16k-combo streaming smoke with this "
                         "admission window")
    ap.add_argument("--report", action="store_true",
                    help="run the matmul performance-study smoke "
                         "(capture + streaming aggregation + speedup "
                         "table, live and offline)")
    ap.add_argument("--lint", action="store_true",
                    help="run the static-analysis smoke (clean example "
                         "+ seeded-defect study through the findings "
                         "formatters)")
    ap.add_argument("--chaos", default=None,
                    choices=("lane", "host", "sigkill"),
                    help="run a deterministic fault-injection smoke "
                         "(examples/chaos/ plans): 'lane' kills lane "
                         "workers and asserts record-set equivalence, "
                         "'host' drives quarantine + probation recovery, "
                         "'sigkill' crashes mid-run, tears a journal "
                         "segment, and asserts resume equivalence")
    ap.add_argument("--chaos-child", action="store_true",
                    help=argparse.SUPPRESS)
    ap.add_argument("--trace", action="store_true",
                    help="run the telemetry smoke: a chaos-armed "
                         "windowed lane study with Chrome-trace output, "
                         "the /metrics endpoint, and span/counter "
                         "assertions (see repro.core.telemetry)")
    ap.add_argument("--status", action="store_true",
                    help="with --trace: also drive the in-place live "
                         "status line while the study runs")
    args = ap.parse_args()
    if args.chaos_child:
        run_chaos_child()
        return
    if args.trace or args.status:
        run_trace(status=args.status)
        return
    if args.chaos:
        {"lane": run_chaos_lane, "host": run_chaos_host,
         "sigkill": run_chaos_sigkill}[args.chaos]()
        return
    if args.lint:
        run_lint()
        return
    if args.report:
        run_perf_report()
        return
    if args.window is not None:
        run_windowed(args.window)
        return
    if args.pool == "ssh":
        run_remote(args.hosts, args.ppnode)
        return
    if args.pool == "lane":
        run_lane()
        return

    study = ParameterStudy(parse_yaml(WDL), registry={"matmulOMP": matmul},
                           root="/tmp/papas_quickstart", name="quickstart")
    instances = study.instances()
    print(f"N_W = {len(instances)} workflow instances "
          f"(paper: 88 = 11 sizes x 8 thread counts)")

    results = study.run()
    ok = sum(1 for r in results.values() if r.status == "ok")
    print(f"completed {ok}/{len(results)}")
    print("profiler:", study.db.runtime_summary())
    print("\nDAG preview (first lines):")
    print("\n".join(study.visualize("ascii").splitlines()[:6]))


if __name__ == "__main__":
    main()
