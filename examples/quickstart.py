"""Quickstart: the paper's Fig. 5 matmul study, end to end.

    PYTHONPATH=src python examples/quickstart.py

Parses the paper's own WDL, expands the 88 workflow instances, runs them
through the study engine (with the task profiler), and prints the
provenance summary + a DAG preview.
"""
import numpy as np

from repro.core import ParameterStudy, parse_yaml

WDL = """
matmulOMP:
  name: Matrix multiply scaling study with OpenMP
  environ:
    OMP_NUM_THREADS: ["1:8"]
  args:
    size: ["16:*2:16384"]
  command: matmul ${args:size} result_${args:size}N_${environ:OMP_NUM_THREADS}T.txt
"""


def matmul(combo):
    n = min(int(combo["args:size"]), 512)      # cap for the demo box
    a = np.ones((n, n), np.float32)
    return float((a @ a)[0, 0])


def main():
    study = ParameterStudy(parse_yaml(WDL), registry={"matmulOMP": matmul},
                           root="/tmp/papas_quickstart", name="quickstart")
    instances = study.instances()
    print(f"N_W = {len(instances)} workflow instances "
          f"(paper: 88 = 11 sizes x 8 thread counts)")

    results = study.run()
    ok = sum(1 for r in results.values() if r.status == "ok")
    print(f"completed {ok}/{len(results)}")
    print("profiler:", study.db.runtime_summary())
    print("\nDAG preview (first lines):")
    print("\n".join(study.visualize("ascii").splitlines()[:6]))


if __name__ == "__main__":
    main()
